//! Figure 2 regeneration bench: strong scaling — simulated time to an
//! ε_D-accurate dual solution vs K for CoCoA+, CoCoA, and mini-batch SGD
//! on the epsilon analogue, with wall-clock per curve.

use cocoa::baselines::minibatch_sgd::{MiniBatchSgd, MiniBatchSgdConfig};
use cocoa::baselines::serial_sdca;
use cocoa::data::partition::random_balanced;
use cocoa::prelude::*;
use cocoa::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("fig2").with_samples(3);
    let data = cocoa::data::synth::paper_dataset("epsilon", 500.0, 42);
    let n = data.n();
    let lambda = 1e-3;
    let eps_d = 1e-3;
    let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
    let d_star = serial_sdca::estimate_d_star(&problem, 42);
    println!("Figure 2 — time to D* − D(α) ≤ {eps_d:.0e} (D* ≈ {d_star:.6})\n");
    println!("{:>4} {:>14} {:>14} {:>14}", "K", "CoCoA+ t(s)", "CoCoA t(s)", "mb-SGD t(s)");

    for k in [2usize, 4, 8, 16] {
        let mut row = [f64::NAN; 3];
        for (mi, plus) in [(0usize, true), (1, false)] {
            b.run(&format!("k{k}_{}", if plus { "plus" } else { "avg" }), || {
                let part = random_balanced(n, k, 42);
                let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
                let solver = SolverSpec::SdcaEpochs { epochs: 1.0 };
                let cfg = if plus {
                    CocoaConfig::cocoa_plus(k, Loss::Hinge, lambda, solver)
                } else {
                    CocoaConfig::cocoa(k, Loss::Hinge, lambda, solver)
                }
                .with_rounds(300)
                .with_gap_tol(0.0);
                let mut tr = Trainer::new(problem, part, cfg);
                let mut cum = 0.0;
                row[mi] = f64::NAN;
                for _ in 0..300 {
                    cum += tr.round() + tr.cfg.comm.round_time(tr.problem.d());
                    if d_star - tr.problem.dual_value(&tr.alpha, &tr.w) <= eps_d {
                        row[mi] = cum;
                        break;
                    }
                }
                black_box(cum)
            });
        }
        b.run(&format!("k{k}_sgd"), || {
            let part = random_balanced(n, k, 42);
            let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
            let mut cfg = MiniBatchSgdConfig::new(k);
            cfg.max_rounds = 4000;
            cfg.gap_every = 25;
            cfg.gap_tol = eps_d;
            let mut sgd = MiniBatchSgd::new(problem, part, cfg);
            let h = sgd.run(Some(d_star));
            row[2] = h
                .time_to_gap(eps_d)
                .map(|(_, t, _)| t)
                .unwrap_or(f64::NAN);
            black_box(h.final_gap())
        });
        let f = |v: f64| if v.is_nan() { "-".into() } else { format!("{v:.3}") };
        println!("{:>4} {:>14} {:>14} {:>14}", k, f(row[0]), f(row[1]), f(row[2]));
    }
    b.report();
}
