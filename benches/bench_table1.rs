//! Table 1 regeneration bench: computes the (n²/K)/σ rows end-to-end
//! (partition + power iteration per block) and prints them in the paper's
//! layout, timing the whole pipeline per dataset/K.

use cocoa::data::partition::random_balanced;
use cocoa::subproblem::sigma::partition_sigma;
use cocoa::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("table1").with_samples(3);
    println!("Table 1 — ratio (n²/K)/σ  (paper: 10–42, slowly decaying in K)\n");
    println!("{:<10} {:>6} {:>10} {:>14}", "dataset", "K", "ratio", "σ");

    for ds in ["news", "real-sim", "rcv1", "covtype"] {
        let data = cocoa::data::synth::paper_dataset(ds, 500.0, 42);
        let n = data.n();
        for k in [16usize, 64, 256] {
            if k > n / 2 {
                continue;
            }
            let mut last = (0.0, 0.0);
            b.run(&format!("sigma_{ds}_k{k}"), || {
                let part = random_balanced(n, k, 42);
                let ps = partition_sigma(&data, &part, 42);
                last = (ps.table1_ratio(n), ps.sigma_sum);
                black_box(ps.sigma_sum)
            });
            println!("{:<10} {:>6} {:>10.3} {:>14.1}", ds, k, last.0, last.1);
        }
    }
    b.report();
}
