//! Figure 3 regeneration bench: the σ' sweep at γ=1, K=8 on the rcv1
//! analogue — convergence speed and the divergence frontier, with the
//! wall-clock of regenerating each σ' curve.

use cocoa::coordinator::StopReason;
use cocoa::data::partition::random_balanced;
use cocoa::prelude::*;
use cocoa::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("fig3").with_samples(3);
    let k = 8usize;
    let lambda = 1e-3;
    let data = cocoa::data::synth::paper_dataset("rcv1", 500.0, 42);
    let n = data.n();
    println!("Figure 3 — σ' sweep at γ=1, K={k} (safe bound σ'=K)\n");
    println!("{:>6} {:>12} {:>10} {:>10}", "σ'", "final gap", "rounds", "status");

    for sp in [1.0, 2.0, 4.0, 6.0, 8.0] {
        let mut summary = (f64::NAN, 0usize, "?");
        b.run(&format!("sigma_prime_{sp}"), || {
            let part = random_balanced(n, k, 42);
            let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
            let cfg = CocoaConfig::cocoa_plus(
                k,
                Loss::Hinge,
                lambda,
                SolverSpec::SdcaEpochs { epochs: 1.0 },
            )
            .with_sigma_prime(sp)
            .with_rounds(100)
            .with_gap_tol(1e-4);
            let mut tr = Trainer::new(problem, part, cfg);
            let h = tr.run();
            summary = (
                h.final_gap(),
                h.rounds_run(),
                match h.stop {
                    StopReason::Diverged => "DIVERGED",
                    StopReason::GapReached => "converged",
                    _ => "budget",
                },
            );
            black_box(h.final_gap())
        });
        println!(
            "{:>6} {:>12.4e} {:>10} {:>10}",
            sp, summary.0, summary.1, summary.2
        );
    }
    b.report();
}
