//! Hot-path micro-benchmarks: the quantities the §Perf optimization pass
//! tracks. Run with `cargo bench --offline` (BENCH_SAMPLES/BENCH_WARMUP
//! env vars shrink/grow the work).

use cocoa::data::partition::random_balanced;
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::linalg::{dense, power_iter, simd, CsrMatrix};
use cocoa::objective::Problem;
use cocoa::prelude::*;
use cocoa::serve::Model;
use cocoa::solver::sdca::SdcaSolver;
use cocoa::solver::{LocalSolveCtx, LocalSolver};
use cocoa::subproblem::{LocalBlock, SubproblemSpec};
use cocoa::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("hotpath");

    // ---- dense kernels -------------------------------------------------
    let x: Vec<f64> = (0..4096).map(|i| (i as f64 * 0.37).sin()).collect();
    let y: Vec<f64> = (0..4096).map(|i| (i as f64 * 0.11).cos()).collect();
    b.run("dense_dot_4096", || black_box(dense::dot(&x, &y)));
    let mut acc = vec![0.0; 4096];
    b.run("dense_axpy_4096", || {
        dense::axpy(0.5, &x, &mut acc);
        black_box(acc[0])
    });

    // ---- CSR row kernels: SIMD dispatch vs forced scalar ----------------
    // The same fully-dense CSR row through both dispatch states — the
    // speedup column of the snapshot comparison is the AVX2 payoff on
    // the gather-free dense-row fast path. `COCOA_NO_SIMD=1` pins a
    // production run to the scalar side of this pair.
    let dense_row = CsrMatrix::from_dense(1, 4096, &x);
    let mut row_acc = vec![0.0; 4096];
    simd::force_scalar(true);
    b.run("csr_row_dot_dense_d4096_scalar", || {
        black_box(dense_row.row_dot(0, &y))
    });
    b.run("csr_row_axpy_dense_d4096_scalar", || {
        dense_row.row_axpy(0, 0.5, &mut row_acc);
        black_box(row_acc[0])
    });
    simd::force_scalar(false);
    b.run("csr_row_dot_dense_d4096_simd", || {
        black_box(dense_row.row_dot(0, &y))
    });
    b.run("csr_row_axpy_dense_d4096_simd", || {
        dense_row.row_axpy(0, 0.5, &mut row_acc);
        black_box(row_acc[0])
    });

    // ---- cache-blocked margin sweep (certificate inner loop) ------------
    let sweep = generate(&SynthConfig::new("b", 4096, 512).density(0.05).seed(9));
    let wv: Vec<f64> = (0..512).map(|i| (i as f64 * 0.19).sin()).collect();
    let mut margins = vec![0.0; 4096];
    b.run("csr_rows_dot_n4096_d512", || {
        sweep.x.rows_dot(0, &wv, &mut margins);
        black_box(margins[0])
    });

    // ---- sparse SDCA epoch (the paper's inner loop) ----------------------
    for (name, n, d, density) in [
        ("sdca_epoch_dense_n2048_d128", 2048usize, 128usize, 1.0),
        ("sdca_epoch_sparse_n8192_d1024", 8192, 1024, 0.01),
    ] {
        let data = generate(&SynthConfig::new("b", n, d).density(density).seed(1));
        let rows: Vec<usize> = (0..n / 4).collect();
        let block = LocalBlock::from_partition(&data, &rows);
        let spec = SubproblemSpec {
            loss: Loss::Hinge,
            lambda: 1e-3,
            n_global: n,
            sigma_prime: 4.0,
            k: 4,
        };
        let w = vec![0.0; d];
        let alpha = vec![0.0; block.n_local()];
        let mut solver = SdcaSolver::new(block.n_local(), 7);
        let ctx = LocalSolveCtx {
            block: &block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha,
        };
        let nnz_per_epoch = block.x().nnz() as f64;
        let r = b.run(name, || black_box(solver.solve(&ctx).steps));
        let secs = r.min().as_secs_f64();
        println!(
            "  {name}: {:.1} Mnnz/s effective",
            2.0 * nnz_per_epoch / secs / 1e6 // dot + axpy touch nnz each
        );
    }

    // ---- duality gap & objective ----------------------------------------
    let data = generate(&SynthConfig::new("b", 8192, 512).density(0.05).seed(2));
    let problem = Problem::new(data, Loss::Hinge, 1e-3);
    let alpha: Vec<f64> = (0..problem.n())
        .map(|i| problem.data.y[i] * ((i % 100) as f64 / 100.0))
        .collect();
    b.run("duality_gap_n8192_d512", || {
        black_box(problem.duality_gap(&alpha))
    });

    // ---- power iteration (Table 1 machinery) ----------------------------
    let data = generate(&SynthConfig::new("b", 4096, 256).density(0.05).seed(3));
    b.run("power_iter_n4096_d256", || {
        black_box(power_iter::spectral_norm_sq(&data.x, 100, 1e-9, 1).sigma)
    });

    // ---- one full coordinator round (K=8): persistent pool vs sequential --
    // The pool spawns its threads once at Trainer::new, so the measured
    // rounds below contain zero thread spawns and zero result allocations.
    let data = generate(&SynthConfig::new("b", 8192, 256).density(0.1).seed(4));
    let part = random_balanced(8192, 8, 1);
    let problem = Problem::new(data, Loss::Hinge, 1e-3);
    let cfg = CocoaConfig::cocoa_plus(
        8,
        Loss::Hinge,
        1e-3,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(1);

    let mut pooled = Trainer::new(
        problem.clone(),
        part.clone(),
        cfg.clone().with_parallel(true),
    );
    assert_eq!(pooled.executor_kind(), "pooled");
    b.run("coordinator_round_k8_n8192_pooled", || {
        black_box(pooled.round())
    });
    println!("  pooled runtime: {}", pooled.comm_stats().runtime_summary());

    let mut sequential = Trainer::new(
        problem.clone(),
        part.clone(),
        cfg.clone().with_parallel(false),
    );
    assert_eq!(sequential.executor_kind(), "sequential");
    b.run("coordinator_round_k8_n8192_sequential", || {
        black_box(sequential.round())
    });

    // ---- the same round through real worker processes (socket executor) --
    // Each round here crosses K Unix-socket hops both ways; the delta vs
    // the pooled line is the true wire + serialization cost per round.
    let socket_cfg = cfg
        .with_executor(ExecutorChoice::Socket)
        .with_socket_worker_bin(env!("CARGO_BIN_EXE_cocoa"));
    let mut socket = Trainer::new(problem, part, socket_cfg);
    assert_eq!(socket.executor_kind(), "socket");
    b.run("coordinator_round_k8_n8192_socket", || {
        black_box(socket.round())
    });

    // ---- certificate evaluation: central pass vs pool-distributed -------
    // The duality-gap certificate (eq. 4) used to be a serial O(nnz) pass
    // on the leader; it is now a K-way shard-partial reduction through the
    // worker pool. Track both so the speedup at gap cadence is visible.
    b.run("certificates_central_n8192_d256", || {
        black_box(pooled.problem.certificates(&pooled.alpha, &pooled.w).gap)
    });
    b.run("certificates_pooled_k8_n8192_d256", || {
        black_box(pooled.eval().gap)
    });
    b.run("certificates_sequential_k8_n8192_d256", || {
        black_box(sequential.eval().gap)
    });
    b.run("certificates_socket_k8_n8192_d256", || {
        black_box(socket.eval().gap)
    });

    // ---- serving predict path (`cocoa serve` per-request cost) ----------
    // What one POST /predict pays: untrusted (col, val) pairs → validated
    // CSR row (sort, merge, zero-drop) → two-lane dot → loss link. The
    // row_dot+link line isolates the scoring kernel from row construction.
    let d = 1024usize;
    let model = Model {
        loss: Loss::Logistic,
        lambda: 1e-3,
        n_train: 0,
        k: 1,
        w: (0..d).map(|i| (i as f64 * 0.37).sin()).collect(),
        alpha: vec![],
        source: "bench".into(),
    };
    // 64 nnz, deliberately unsorted (stride-533 walk over 1024 columns)
    let pairs: Vec<(usize, f64)> = (0..64)
        .map(|i| ((i * 533 + 17) % d, (i as f64 * 0.13).cos()))
        .collect();
    b.run("serve_predict_single_64nnz_d1024", || {
        black_box(model.predict_pairs(&pairs).unwrap().score)
    });
    let row = CsrMatrix::row_from_pairs(d, &pairs).unwrap();
    b.run("serve_row_dot_link_64nnz_d1024", || {
        black_box(model.prediction_from_score(row.row_dot(0, &model.w)).value)
    });
    let batch: Vec<Vec<(usize, f64)>> = (0..64)
        .map(|r| {
            (0..64)
                .map(|i| ((i * 533 + 17 * (r + 1)) % d, (i as f64 * 0.13 + r as f64).cos()))
                .collect()
        })
        .collect();
    b.run("serve_predict_batch64_64nnz_d1024", || {
        let mut acc = 0.0;
        for p in &batch {
            acc += model.predict_pairs(p).unwrap().score;
        }
        black_box(acc)
    });

    b.report();
    // CI sets BENCH_JSON=BENCH_<pr>.json to capture the machine-readable
    // report as a build artifact.
    b.maybe_write_json_env();
}
