//! Quickstart: train a hinge-loss SVM with CoCoA+ on synthetic data and
//! watch the duality-gap certificate fall.
//!
//!     cargo run --release --example quickstart

use cocoa::prelude::*;

fn main() {
    // 1. Data: 4,000 unit-norm points in 100 dims with a planted margin.
    let data = cocoa::data::synth::generate(
        &cocoa::data::synth::SynthConfig::new("quickstart", 4_000, 100)
            .density(0.25)
            .label_noise(0.05)
            .seed(1),
    );
    println!(
        "dataset: n={} d={} density={:.3}",
        data.n(),
        data.d(),
        data.density()
    );

    // 2. Partition over K=8 simulated workers.
    let k = 8;
    let partition = cocoa::data::partition::random_balanced(data.n(), k, 1);

    // 3. CoCoA+ — additive aggregation with the safe σ' = γK, one local
    //    SDCA epoch per round.
    let lambda = 1e-3;
    let problem = Problem::new(data, Loss::Hinge, lambda);
    let cfg = CocoaConfig::cocoa_plus(
        k,
        Loss::Hinge,
        lambda,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(50)
    .with_gap_tol(1e-4);
    let mut trainer = Trainer::new(problem, partition, cfg);

    // 4. Train; every record carries a primal-dual certificate.
    let history = trainer.run();
    for r in &history.records {
        println!(
            "round {:>3}  gap {:.4e}  (P {:.6}  D {:.6})",
            r.round, r.gap, r.primal, r.dual
        );
    }
    println!(
        "\nstopped: {:?} after {} rounds; final gap {:.3e}",
        history.stop,
        history.rounds_run(),
        history.final_gap()
    );
    println!(
        "train 0/1 error: {:.4}",
        trainer.problem.data.classification_error(&trainer.w)
    );
    assert!(history.final_gap() < 1e-3, "quickstart should converge");
}
