//! Train on a real LibSVM file (generating a synthetic one first if no
//! path is given) — demonstrates the ingestion path the paper's datasets
//! (covtype/rcv1/epsilon/news20/real-sim) drop into unchanged.
//!
//!     cargo run --release --example libsvm_train [-- /path/to/data.svm]

use cocoa::prelude::*;
use std::path::Path;

fn main() {
    let arg = std::env::args().nth(1);
    let (path, cleanup) = match arg {
        Some(p) => (p, false),
        None => {
            // Self-contained demo: write a covtype-like sample to /tmp.
            let p = "/tmp/cocoa_demo.svm".to_string();
            let data = cocoa::data::synth::paper_dataset("covtype", 500.0, 9);
            cocoa::data::libsvm::save(&data, Path::new(&p)).expect("write demo data");
            println!("(no path given; wrote demo dataset to {p})");
            (p, true)
        }
    };

    let data = cocoa::data::libsvm::load(Path::new(&path), None)
        .unwrap_or_else(|e| panic!("failed to parse {path}: {e}"));
    println!(
        "loaded {}: n={} d={} density={:.4} positives={:.2}",
        path,
        data.n(),
        data.d(),
        data.density(),
        data.positive_fraction()
    );

    let k = 8.min(data.n() / 4).max(1);
    let lambda = 1e-3;
    let partition = cocoa::data::partition::random_balanced(data.n(), k, 13);
    let mut normalized = data;
    normalized.normalize_rows(); // paper setup: ‖x_i‖ ≤ 1
    let problem = Problem::new(normalized, Loss::Hinge, lambda);
    let cfg = CocoaConfig::cocoa_plus(
        k,
        Loss::Hinge,
        lambda,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(100)
    .with_gap_tol(1e-4);
    let mut trainer = Trainer::new(problem, partition, cfg);
    let hist = trainer.run();

    println!(
        "K={k}: {:?} after {} rounds, gap {:.3e}, train error {:.4}",
        hist.stop,
        hist.rounds_run(),
        hist.final_gap(),
        trainer.problem.data.classification_error(&trainer.w)
    );
    if cleanup {
        std::fs::remove_file(&path).ok();
    }
}
