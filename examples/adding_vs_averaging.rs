//! The paper's headline experiment in miniature: *adding* (CoCoA+, γ=1,
//! σ'=K) versus *averaging* (CoCoA, γ=1/K, σ'=1) as K grows, at identical
//! local work per round.
//!
//!     cargo run --release --example adding_vs_averaging

use cocoa::prelude::*;
use cocoa::report::ascii_plot::{render, PlotCfg, Series};

fn rounds_to_gap(plus: bool, k: usize, data: &Dataset, lambda: f64, tol: f64) -> Option<usize> {
    let partition = cocoa::data::partition::random_balanced(data.n(), k, 7);
    let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
    let solver = SolverSpec::SdcaEpochs { epochs: 1.0 };
    let cfg = if plus {
        CocoaConfig::cocoa_plus(k, Loss::Hinge, lambda, solver)
    } else {
        CocoaConfig::cocoa(k, Loss::Hinge, lambda, solver)
    }
    .with_rounds(400)
    .with_gap_tol(tol);
    let mut trainer = Trainer::new(problem, partition, cfg);
    let hist = trainer.run();
    hist.time_to_gap(tol).map(|(round, _, _)| round + 1)
}

fn main() {
    let data = cocoa::data::synth::generate(
        &cocoa::data::synth::SynthConfig::new("scaling", 2_048, 64)
            .density(0.3)
            .seed(3),
    );
    let lambda = 1e-3;
    let tol = 1e-3;
    let ks = [2usize, 4, 8, 16, 32];

    println!("rounds to duality gap ≤ {tol:e} (1 local epoch/round):\n");
    println!("{:>4} {:>14} {:>14} {:>8}", "K", "adding (γ=1)", "avg (γ=1/K)", "ratio");
    let mut xs = Vec::new();
    let (mut add_r, mut avg_r) = (Vec::new(), Vec::new());
    for &k in &ks {
        let add = rounds_to_gap(true, k, &data, lambda, tol);
        let avg = rounds_to_gap(false, k, &data, lambda, tol);
        let ratio = match (add, avg) {
            (Some(a), Some(b)) => format!("{:.1}x", b as f64 / a as f64),
            _ => "-".into(),
        };
        println!(
            "{:>4} {:>14} {:>14} {:>8}",
            k,
            add.map(|r| r.to_string()).unwrap_or("-".into()),
            avg.map(|r| r.to_string()).unwrap_or("-".into()),
            ratio
        );
        xs.push(k as f64);
        add_r.push(add.map(|r| r as f64).unwrap_or(f64::NAN));
        avg_r.push(avg.map(|r| r as f64).unwrap_or(f64::NAN));
    }

    let chart = render(
        "rounds-to-ε vs K (log-log): flat = strong scaling",
        &[
            Series::new("adding (CoCoA+)", xs.clone(), add_r.clone(), '+'),
            Series::new("averaging (CoCoA)", xs, avg_r.clone(), 'o'),
        ],
        &PlotCfg::default(),
    );
    println!("\n{chart}");
    println!("Corollary 9: averaging needs O(K) more rounds; adding is K-independent.");

    // sanity: at the largest K that both finished, adding must win
    if let (Some(&a), Some(&b)) = (add_r.last(), avg_r.last()) {
        if a.is_finite() && b.is_finite() {
            assert!(a <= b, "adding ({a}) should need ≤ rounds than averaging ({b})");
        }
    }
}
