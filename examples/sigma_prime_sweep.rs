//! Figure-3-style σ' sweep: with additive aggregation (γ=1) on K=8
//! workers, how does the subproblem parameter σ' trade off speed against
//! safety? The safe bound σ' = γK always converges; smaller σ' is faster
//! until — below σ'_min (Eq. 11) — the iteration diverges.
//!
//!     cargo run --release --example sigma_prime_sweep

use cocoa::coordinator::StopReason;
use cocoa::prelude::*;

fn main() {
    let k = 8usize;
    let lambda = 1e-3;
    let data = cocoa::data::synth::generate(
        &cocoa::data::synth::SynthConfig::new("sweep", 2_000, 128)
            .density(0.1)
            .nonneg(true)
            .seed(11),
    );
    let partition = cocoa::data::partition::random_balanced(data.n(), k, 11);

    // Where does the theory say the floor is? σ'_min per Eq. (11) is data-
    // dependent; report the spectral diagnostics so the sweep can be read
    // against them.
    let ps = cocoa::subproblem::sigma::partition_sigma(&data, &partition, 11);
    println!(
        "partition diagnostics: σ_max={:.2} σ=Σσ_k·n_k={:.1} (safe σ'=γK={k})\n",
        ps.sigma_max(),
        ps.sigma_sum
    );

    println!(
        "{:>6} {:>12} {:>12} {:>10}",
        "σ'", "final gap", "rounds run", "status"
    );
    for sp in [0.5, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0] {
        let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
        let cfg = CocoaConfig::cocoa_plus(
            k,
            Loss::Hinge,
            lambda,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_sigma_prime(sp)
        .with_rounds(80)
        .with_gap_tol(1e-4);
        let mut trainer = Trainer::new(problem, partition.clone(), cfg);
        let hist = trainer.run();
        let status = match hist.stop {
            StopReason::Diverged => "DIVERGED",
            StopReason::GapReached => "converged",
            _ => "budget",
        };
        println!(
            "{:>6} {:>12.4e} {:>12} {:>10}",
            sp,
            hist.final_gap(),
            hist.rounds_run(),
            status
        );
    }
    println!(
        "\nReading: σ' slightly below K is fastest; far below σ'_min the\n\
         updates over-shoot and the gap blows up — exactly Figure 3."
    );
}
