//! END-TO-END THREE-LAYER DRIVER — proves every layer composes on a real
//! workload:
//!
//!   L1  Pallas SDCA-block + matvec kernels (python/compile/kernels/*)
//!   L2  JAX local_sdca / duality_gap graphs (python/compile/model.py)
//!       — both AOT-lowered once to artifacts/*.hlo.txt by `make artifacts`
//!   L3  this binary: the Rust CoCoA+ coordinator, with each worker's
//!       local solve *and* the leader's gap certificate executing the AOT
//!       artifacts through PJRT. No Python anywhere at run time.
//!
//! The run trains a distributed hinge-SVM on a synthetic dataset shaped to
//! the compiled artifact (K×m rows, d features), logs the certificate
//! trajectory, and cross-checks every XLA number against the native Rust
//! implementation — including a bit-level trajectory comparison of the
//! XLA solver vs the native SDCA solver fed the same coordinate stream.
//!
//!     make artifacts && cargo run --release --example e2e_xla_pipeline
//!
//! The recorded output of this driver lives in EXPERIMENTS.md §End-to-end.

use cocoa::coordinator::worker::Worker;
use cocoa::prelude::*;
use cocoa::runtime::artifact::{default_artifacts_dir, Manifest};
use cocoa::runtime::pjrt::PjrtRuntime;
use cocoa::runtime::{XlaGapEvaluator, XlaSdcaProgram, XlaSdcaSolver};
use cocoa::solver::sdca::SdcaSolver;
use cocoa::subproblem::LocalBlock;
use std::sync::Arc;

fn main() {
    let dir = default_artifacts_dir()
        .expect("artifacts not found — run `make artifacts` first");
    let manifest = Manifest::load(&dir).expect("manifest parse");
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    println!("PJRT platform: {}", rt.platform());

    let program = Arc::new(XlaSdcaProgram::load(&rt, &manifest).expect("load local_sdca"));
    let gap_eval = XlaGapEvaluator::load(&rt, &manifest).expect("load duality_gap");
    let (m, d, h) = (program.m, program.d, program.h);
    let k = 4usize;
    let n = k * m; // fill the gap artifact exactly: n = 1024 by default
    assert!(n <= gap_eval.n, "gap artifact too small for K*m rows");
    println!("artifacts: local_sdca(m={m},d={d},H={h}), duality_gap(n={}, d={})", gap_eval.n, gap_eval.d);

    // ---- workload: dense synthetic SVM shaped to the artifact ----------
    let data = cocoa::data::synth::generate(
        &cocoa::data::synth::SynthConfig::new("e2e", n, d)
            .density(1.0)
            .label_noise(0.05)
            .seed(5),
    );
    let lambda = 1e-2;
    let partition = cocoa::data::partition::random_balanced(n, k, 5);
    let problem = Problem::new(data.clone(), Loss::Hinge, lambda);

    // ---- trainer with XLA-backed local solvers -------------------------
    let seed = 42u64;
    let blocks = LocalBlock::split(&problem.data, &partition);
    let solvers: Vec<Box<dyn cocoa::solver::LocalSolver>> = blocks
        .iter()
        .enumerate()
        .map(|(wk, block)| {
            let s = XlaSdcaSolver::new(
                Arc::clone(&program),
                block,
                lambda * n as f64,
                k as f64, // safe σ' = γK with γ=1
                Worker::round_seed(seed, 0, wk),
            )
            .expect("pack block");
            Box::new(s) as Box<dyn cocoa::solver::LocalSolver>
        })
        .collect();
    let cfg = CocoaConfig::cocoa_plus(k, Loss::Hinge, lambda, SolverSpec::Sdca { h })
        .with_rounds(12)
        .with_gap_tol(1e-5)
        .with_seed(seed)
        .with_parallel(false); // PJRT wrappers run single-threaded
    let mut trainer = Trainer::with_solvers(problem, partition.clone(), cfg, solvers);

    // ---- train, certifying each round through the XLA gap graph --------
    // The trainer works in its permuted-contiguous layout: feed the XLA
    // gap graph the trainer's shared dataset so (X, y, α) stay aligned.
    let x_dense = trainer.problem.data.x.to_dense();
    let y_layout = trainer.problem.data.y.clone();
    println!("\n{:>5} {:>14} {:>14} {:>12} {:>12}", "round", "P (xla)", "D (xla)", "gap (xla)", "gap (rust)");
    let mut last_gap = f64::INFINITY;
    for round in 0..12 {
        trainer.round();
        let certs_xla = gap_eval
            .certificates(&x_dense, n, d, &y_layout, &trainer.alpha, lambda)
            .expect("XLA gap eval");
        let certs_rs = trainer.problem.certificates(&trainer.alpha, &trainer.w);
        println!(
            "{:>5} {:>14.8} {:>14.8} {:>12.4e} {:>12.4e}",
            round, certs_xla.primal, certs_xla.dual, certs_xla.gap, certs_rs.gap
        );
        // L2 gap graph and native Rust objective must agree to float noise.
        assert!(
            (certs_xla.gap - certs_rs.gap).abs() < 1e-8,
            "XLA and native certificates disagree: {} vs {}",
            certs_xla.gap,
            certs_rs.gap
        );
        last_gap = certs_xla.gap;
        if last_gap < 1e-5 {
            break;
        }
    }
    assert!(last_gap < 1e-2, "e2e training did not converge: gap {last_gap}");
    println!("\ntraining converged through the full Rust→PJRT→XLA(Pallas) stack ✓");

    // ---- trajectory identity: XLA solver ≡ native SDCA solver ----------
    // Same block, same seed ⇒ same coordinate stream ⇒ near-identical Δα.
    let block = LocalBlock::from_partition(&data, &partition.parts[0]);
    let spec = *trainer.spec();
    let w0 = vec![0.0; d];
    let a0 = vec![0.0; block.n_local()];
    let ctx = cocoa::solver::LocalSolveCtx {
        block: &block,
        spec: &spec,
        w: &w0,
        alpha_local: &a0,
    };
    let mut xla_solver =
        XlaSdcaSolver::new(Arc::clone(&program), &block, lambda * n as f64, k as f64, 123)
            .expect("pack");
    let mut native = SdcaSolver::new(h, 123);
    use cocoa::solver::LocalSolver as _;
    let u_xla = xla_solver.solve(&ctx);
    let u_nat = native.solve(&ctx);
    let max_da_err = u_xla
        .delta_alpha
        .iter()
        .zip(&u_nat.delta_alpha)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let max_dw_err = u_xla
        .delta_w
        .iter()
        .zip(&u_nat.delta_w)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "trajectory identity (H={h} steps): max|Δα_xla−Δα_rust|={max_da_err:.2e}, \
         max|Δw_xla−Δw_rust|={max_dw_err:.2e}"
    );
    assert!(max_da_err < 1e-9 && max_dw_err < 1e-9, "trajectories diverged");
    println!("native Rust and AOT-XLA local solvers are trajectory-identical ✓");
}
