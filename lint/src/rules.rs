//! The rule families, each a linear scan over a
//! [`FileAnalysis`]. Scope and rationale for every rule live in
//! `ANALYSIS.md` at the repo root; diagnostics carry `file:line` and are
//! suppressible with `// lint:allow(<rule>) -- <reason>`.

use crate::analysis::FileAnalysis;
use crate::lexer::TokKind;

/// One finding. `path` is relative to the lint root.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub path: String,
    pub line: u32,
    pub msg: String,
}

pub const RULE_NO_PANIC: &str = "no_panic";
pub const RULE_DETERMINISM: &str = "determinism";
pub const RULE_UNSAFE_SAFETY: &str = "unsafe_safety";
pub const RULE_LOCK_ORDER: &str = "lock_order";
pub const RULE_ARITH_OVERFLOW: &str = "arith_overflow";
pub const RULE_WAIVER: &str = "waiver";

pub const ALL_RULES: &[(&str, &str)] = &[
    (RULE_NO_PANIC, "no unwrap/expect/panic!/indexing on serving or parsing surfaces"),
    (RULE_DETERMINISM, "no wall clock, hash iteration, or arrival-order gathers in round code"),
    (RULE_UNSAFE_SAFETY, "every unsafe block or impl carries an adjacent // SAFETY: comment"),
    (RULE_LOCK_ORDER, "nested lock acquisitions follow admin < model < w_shared"),
    (RULE_ARITH_OVERFLOW, "size/length math on the wire codec uses checked_add/checked_mul"),
    (RULE_WAIVER, "lint:allow waivers must carry a `-- reason`"),
];

/// Files where a panic is an availability bug: request handling and
/// input parsing. Matched as suffixes of the root-relative path.
pub const NO_PANIC_SURFACES: &[&str] = &[
    "coordinator/wire.rs",
    "serve/http.rs",
    "serve/router.rs",
    "serve/predict.rs",
    "data/libsvm.rs",
    "telemetry/writer.rs",
    "telemetry/checker.rs",
    "telemetry/summary.rs",
];

/// Directories whose code runs inside optimization rounds, where the
/// three-executor bit-identity invariant holds. Wall clock and
/// hash-ordered iteration are banned here; timing goes through
/// `util::timer` (`Stopwatch` / `Deadline`), keyed aggregation through
/// `BTreeMap`, and gathers through per-worker-index `recv()`.
pub const DETERMINISM_DIRS: &[&str] = &["driver/", "solver/", "coordinator/", "telemetry/"];

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Files whose `+`/`*` operate on message-derived lengths: a silent wrap
/// in a size computation emits an under-sized frame prefix and desyncs
/// the stream for every later frame. Matched as suffixes of the
/// root-relative path.
pub const ARITH_OVERFLOW_SURFACES: &[&str] = &["coordinator/wire.rs"];

/// Identifier fragments that mark an operand as a size/length quantity.
const SIZE_WORDS: &[&str] = &["len", "size", "byte", "word", "total", "nnz"];

const HASH_COLLECTIONS: &[&str] = &["HashMap", "HashSet"];
const WALL_CLOCK: &[&str] = &["Instant", "SystemTime"];

/// Keywords that may legitimately precede `[` without it being an index
/// expression (slice patterns, `for x in arr[..]` is still caught via
/// the ident before `[`, but `let [a, b] = …` is not an index).
const KEYWORDS: &str = "as break const continue crate dyn else enum extern fn for if impl in let loop match mod move mut pub ref return static struct super trait type unsafe use where while yield";

/// The declared lock hierarchy: a lock may only be acquired while
/// holding locks of strictly lower rank.
pub const LOCK_RANKS: &[(&str, u32)] = &[("admin", 0), ("model", 1), ("w_shared", 2)];

const LOCK_METHODS: &[&str] = &["lock", "read", "write", "try_lock", "try_read", "try_write"];

/// Functions that acquire a ranked lock on the caller's behalf:
/// (function name, lock it takes, does the guard escape to the caller).
/// A non-escaping acquirer releases before returning, so it only has to
/// be *consistent* with what the caller already holds; an escaping one
/// joins the caller's held set.
const ACQUIRER_FNS: &[(&str, &str, bool)] = &[
    ("admin_guard", "admin", true),
    ("swap_model", "model", false),
    ("model", "model", false),
];

fn is_keyword(s: &str) -> bool {
    KEYWORDS.split_whitespace().any(|k| k == s)
}

/// Run every rule family over one analyzed file.
pub fn check_file(fa: &FileAnalysis) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if NO_PANIC_SURFACES.iter().any(|s| fa.rel.ends_with(s)) {
        check_no_panic(fa, &mut out);
    }
    if DETERMINISM_DIRS.iter().any(|d| fa.rel.starts_with(d)) {
        check_determinism(fa, &mut out);
    }
    if ARITH_OVERFLOW_SURFACES.iter().any(|s| fa.rel.ends_with(s)) {
        check_arith_overflow(fa, &mut out);
    }
    check_unsafe_safety(fa, &mut out);
    check_lock_order(fa, &mut out);
    check_waiver_format(fa, &mut out);
    out
}

fn push(
    out: &mut Vec<Diagnostic>,
    fa: &FileAnalysis,
    rule: &'static str,
    line: u32,
    message: String,
) {
    if !fa.waived(rule, line) {
        out.push(Diagnostic {
            rule,
            path: fa.rel.clone(),
            line,
            msg: message,
        });
    }
}

fn check_no_panic(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    for (i, t) in fa.toks.iter().enumerate() {
        if fa.in_test[i] || fa.in_attr[i] {
            continue;
        }
        if is_panicky_call(fa, i, "unwrap") || is_panicky_call(fa, i, "expect") {
            let msg = format!("`.{}()` is forbidden on a no-panic surface", t.text);
            push(out, fa, RULE_NO_PANIC, t.line, msg);
            continue;
        }
        if is_panic_macro(fa, i) {
            let msg = format!("`{}!` is forbidden on a no-panic surface", t.text);
            push(out, fa, RULE_NO_PANIC, t.line, msg);
            continue;
        }
        if t.is(TokKind::Punct, "[") && is_index_bracket(fa, i) {
            let target = fa.prev_tok(i).map(|p| p.text.clone()).unwrap_or_default();
            let msg = format!("direct `{target}[..]` indexing; use .get()/checked splits");
            push(out, fa, RULE_NO_PANIC, t.line, msg);
        }
    }
}

fn is_panicky_call(fa: &FileAnalysis, i: usize, name: &str) -> bool {
    if !fa.toks[i].is(TokKind::Ident, name) {
        return false;
    }
    let after_dot = fa.prev_tok(i).is_some_and(|p| p.is(TokKind::Punct, "."));
    let called = fa.next_tok(i).is_some_and(|n| n.is(TokKind::Punct, "("));
    after_dot && called
}

fn is_panic_macro(fa: &FileAnalysis, i: usize) -> bool {
    let t = &fa.toks[i];
    if t.kind != TokKind::Ident || !PANIC_MACROS.contains(&t.text.as_str()) {
        return false;
    }
    fa.next_tok(i).is_some_and(|n| n.is(TokKind::Punct, "!"))
}

fn is_index_bracket(fa: &FileAnalysis, i: usize) -> bool {
    match fa.prev_tok(i) {
        Some(p) if p.kind == TokKind::Ident => !is_keyword(&p.text),
        Some(p) => p.is(TokKind::Punct, ")") || p.is(TokKind::Punct, "]"),
        None => false,
    }
}

fn is_size_word(name: &str) -> bool {
    let lower = name.to_ascii_lowercase();
    SIZE_WORDS.iter().any(|w| lower.contains(w))
}

/// Any identifier within 3 non-comment tokens on either side of `i` that
/// names a size/length quantity.
fn window_mentions_size(fa: &FileAnalysis, i: usize) -> bool {
    let mut seen = 0;
    for t in fa.toks[i + 1..].iter() {
        if t.kind == TokKind::Comment {
            continue;
        }
        if t.kind == TokKind::Ident && is_size_word(&t.text) {
            return true;
        }
        seen += 1;
        if seen == 3 {
            break;
        }
    }
    seen = 0;
    for t in fa.toks[..i].iter().rev() {
        if t.kind == TokKind::Comment {
            continue;
        }
        if t.kind == TokKind::Ident && is_size_word(&t.text) {
            return true;
        }
        seen += 1;
        if seen == 3 {
            break;
        }
    }
    false
}

/// Binary `+`/`*` whose neighborhood mentions a size/length identifier
/// must be `checked_add`/`checked_mul` (or carry a waiver). Compound
/// assignments (`+=`) are out of scope: they accumulate against an
/// already-validated bound, not into a length prefix.
fn check_arith_overflow(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    for (i, t) in fa.toks.iter().enumerate() {
        if fa.in_test[i] || fa.in_attr[i] || t.kind != TokKind::Punct {
            continue;
        }
        let checked = match t.text.as_str() {
            "+" => "checked_add",
            "*" => "checked_mul",
            _ => continue,
        };
        // Binary use only: an operand must sit on the left (rules out
        // deref `*x`, `use …::*`, and `&*`).
        let binary = fa.prev_tok(i).is_some_and(|p| {
            (matches!(p.kind, TokKind::Ident | TokKind::Number) && !is_keyword(&p.text))
                || p.is(TokKind::Punct, ")")
                || p.is(TokKind::Punct, "]")
        });
        if !binary || fa.next_tok(i).is_some_and(|n| n.is(TokKind::Punct, "=")) {
            continue;
        }
        if window_mentions_size(fa, i) {
            let msg = format!(
                "unchecked `{}` on size/length math; use {checked} (or waive with a reason)",
                t.text
            );
            push(out, fa, RULE_ARITH_OVERFLOW, t.line, msg);
        }
    }
}

fn check_determinism(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    for (i, t) in fa.toks.iter().enumerate() {
        if fa.in_test[i] || fa.in_attr[i] || t.kind != TokKind::Ident {
            continue;
        }
        let name = t.text.as_str();
        if HASH_COLLECTIONS.contains(&name) {
            let msg = format!("{name} iteration order varies; use BTreeMap/BTreeSet");
            push(out, fa, RULE_DETERMINISM, t.line, msg);
        } else if WALL_CLOCK.contains(&name) {
            let msg = format!("{name} is wall clock; route through util::timer");
            push(out, fa, RULE_DETERMINISM, t.line, msg);
        } else if name == "try_iter" {
            let msg = "try_iter drains in arrival order; recv() per worker".to_string();
            push(out, fa, RULE_DETERMINISM, t.line, msg);
        } else if is_rx_name(name) && is_arrival_order_gather(fa, i) {
            let msg = format!("receiver `{name}` gathered in arrival order");
            push(out, fa, RULE_DETERMINISM, t.line, msg);
        }
    }
}

fn is_rx_name(name: &str) -> bool {
    name == "rx" || name.ends_with("_rx")
}

/// `for upd in rx { … }`, `rx.iter()`, `rx.into_iter()` — gathers whose
/// order depends on which worker finished first.
fn is_arrival_order_gather(fa: &FileAnalysis, i: usize) -> bool {
    if fa.prev_tok(i).is_some_and(|p| p.is(TokKind::Ident, "in")) {
        return true;
    }
    if !fa.next_tok(i).is_some_and(|n| n.is(TokKind::Punct, ".")) {
        return false;
    }
    let m = match fa.toks.get(i + 2) {
        Some(m) => m,
        None => return false,
    };
    m.is(TokKind::Ident, "iter") || m.is(TokKind::Ident, "into_iter")
}

fn check_unsafe_safety(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    for (i, t) in fa.toks.iter().enumerate() {
        if fa.in_test[i] || fa.in_attr[i] || !t.is(TokKind::Ident, "unsafe") {
            continue;
        }
        if !fa.safety_adjacent(t.line) {
            let msg = "unsafe without an adjacent // SAFETY: comment".to_string();
            push(out, fa, RULE_UNSAFE_SAFETY, t.line, msg);
        }
    }
}

struct HeldLock {
    name: String,
    rank: u32,
    depth: u32,
    line: u32,
}

fn rank_of(name: &str) -> Option<u32> {
    LOCK_RANKS.iter().find(|(n, _)| *n == name).map(|(_, r)| *r)
}

/// `<name>.lock()` / `.read()` / `.write()` / `try_*` on a ranked lock.
fn is_lock_call(fa: &FileAnalysis, i: usize) -> bool {
    if !fa.next_tok(i).is_some_and(|n| n.is(TokKind::Punct, ".")) {
        return false;
    }
    let method = match fa.toks.get(i + 2) {
        Some(m) if m.kind == TokKind::Ident => m.text.as_str(),
        _ => return false,
    };
    if !LOCK_METHODS.contains(&method) {
        return false;
    }
    fa.toks.get(i + 3).is_some_and(|c| c.is(TokKind::Punct, "("))
}

fn check_lock_order(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    let mut held: Vec<HeldLock> = Vec::new();
    for (i, t) in fa.toks.iter().enumerate() {
        if t.is(TokKind::Punct, "}") {
            // A guard lives until its enclosing block closes.
            held.retain(|h| h.depth <= fa.depth[i]);
            continue;
        }
        if fa.in_test[i] || fa.in_attr[i] || t.kind != TokKind::Ident {
            continue;
        }
        if let Some(rank) = rank_of(&t.text) {
            if is_lock_call(fa, i) {
                lock_event(fa, out, &mut held, &t.text, rank, fa.depth[i], t.line, true);
                continue;
            }
        }
        let acq = ACQUIRER_FNS.iter().find(|(f, _, _)| *f == t.text.as_str());
        if let Some(&(_, lock, escaping)) = acq {
            let called = fa.next_tok(i).is_some_and(|n| n.is(TokKind::Punct, "("));
            let is_def = fa.prev_tok(i).is_some_and(|p| p.is(TokKind::Ident, "fn"));
            if called && !is_def {
                let rank = rank_of(lock).unwrap_or(u32::MAX);
                lock_event(fa, out, &mut held, lock, rank, fa.depth[i], t.line, escaping);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn lock_event(
    fa: &FileAnalysis,
    out: &mut Vec<Diagnostic>,
    held: &mut Vec<HeldLock>,
    name: &str,
    rank: u32,
    depth: u32,
    line: u32,
    holds: bool,
) {
    for h in held.iter() {
        if h.name == name {
            let msg = format!("`{name}` re-acquired while held since line {}", h.line);
            push(out, fa, RULE_LOCK_ORDER, line, msg);
        } else if h.rank > rank {
            let msg = format!(
                "`{name}` (rank {rank}) acquired while `{}` (rank {}, line {}) is held",
                h.name, h.rank, h.line
            );
            push(out, fa, RULE_LOCK_ORDER, line, msg);
        }
    }
    if holds {
        held.push(HeldLock {
            name: name.to_string(),
            rank,
            depth,
            line,
        });
    }
}

fn check_waiver_format(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    for w in &fa.waivers {
        if !w.has_reason {
            out.push(Diagnostic {
                rule: RULE_WAIVER,
                path: fa.rel.clone(),
                line: w.line,
                msg: "lint:allow waiver missing a `-- reason`".to_string(),
            });
        }
        for r in &w.rules {
            if !ALL_RULES.iter().any(|(n, _)| n == r) {
                out.push(Diagnostic {
                    rule: RULE_WAIVER,
                    path: fa.rel.clone(),
                    line: w.line,
                    msg: format!("waiver names unknown rule `{r}`"),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diags(rel: &str, src: &str) -> Vec<Diagnostic> {
        check_file(&FileAnalysis::build(rel, src))
    }

    #[test]
    fn unwrap_flagged_only_on_surfaces() {
        let src = "fn f() { x.unwrap(); }\n";
        assert_eq!(diags("serve/http.rs", src).len(), 1);
        assert_eq!(diags("solver/sdca.rs", src).len(), 0);
    }

    #[test]
    fn unwrap_or_family_is_allowed() {
        let src = "fn f() { x.unwrap_or(0); y.unwrap_or_else(id); z.unwrap_or_default(); }\n";
        assert!(diags("serve/http.rs", src).is_empty());
    }

    #[test]
    fn indexing_heuristics() {
        let flagged = "fn f() { let a = buf[0]; }\n";
        assert_eq!(diags("serve/http.rs", flagged).len(), 1);
        let ok = "fn f(x: [u8; 4]) { let [a, b] = pair; let v = vec![1]; let s: &[u8] = q; }\n";
        let d = diags("serve/http.rs", ok);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn panic_macros_flagged() {
        let src = "fn f() { if bad { panic!(\"no\"); } else { unreachable!() } }\n";
        assert_eq!(diags("coordinator/wire.rs", src).len(), 2);
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); buf[0]; }\n}\n";
        assert!(diags("serve/http.rs", src).is_empty());
    }

    #[test]
    fn waiver_suppresses_with_reason() {
        let src = "fn f() {\n    // lint:allow(no_panic) -- checked two lines up\n    x.unwrap();\n}\n";
        assert!(diags("serve/http.rs", src).is_empty());
    }

    #[test]
    fn reasonless_waiver_is_itself_flagged() {
        let src = "fn f() {\n    // lint:allow(no_panic)\n    x.unwrap();\n}\n";
        let d = diags("serve/http.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RULE_WAIVER);
    }

    #[test]
    fn determinism_bans_hash_and_clock() {
        let src = "use std::collections::HashMap;\nfn f() { let t = Instant::now(); }\n";
        let d = diags("driver/train.rs", src);
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| x.rule == RULE_DETERMINISM));
        assert!(diags("serve/http.rs", src).is_empty());
    }

    #[test]
    fn gather_order_patterns() {
        let src = "fn g() { for upd in rx { push(upd); } reply_rx.iter().count(); q.try_iter(); }\n";
        let d = diags("coordinator/pool.rs", src);
        assert_eq!(d.len(), 3, "{d:?}");
        let ok = "fn g() { let r = reply_rx.recv(); for (li, &gi) in parts.iter() {} }\n";
        assert!(diags("coordinator/pool.rs", ok).is_empty());
    }

    #[test]
    fn arith_overflow_scoped_to_wire_size_math() {
        let bad = "fn f() { let total = 4 + header_bytes.len() + 8 * words; }\n";
        let d = diags("coordinator/wire.rs", bad);
        assert_eq!(d.len(), 3, "{d:?}");
        assert!(d.iter().all(|x| x.rule == RULE_ARITH_OVERFLOW));
        // identical code off-surface is not the wire codec's problem
        assert!(diags("solver/sdca.rs", bad).is_empty());
    }

    #[test]
    fn arith_overflow_ignores_non_size_math_and_compound_assign() {
        let ok = "fn f() { got += n; let y = a * b + c; let s = acc | (u64::from(b) << (8 * i)); }\n";
        let d = diags("coordinator/wire.rs", ok);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn arith_overflow_waivable_with_reason() {
        let src = "fn f() {\n    // lint:allow(arith_overflow) -- bounded by MAX_SECTIONS above\n    let total = 4 + header_bytes.len();\n}\n";
        assert!(diags("coordinator/wire.rs", src).is_empty());
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f() { unsafe { q() } }\n";
        assert_eq!(diags("linalg/sparse.rs", bad).len(), 1);
        let good = "fn f() {\n    // SAFETY: q upholds its contract here.\n    unsafe { q() }\n}\n";
        assert!(diags("linalg/sparse.rs", good).is_empty());
    }

    #[test]
    fn lock_inversion_detected_and_order_allowed() {
        let bad = "fn f(s: &S) { let g = s.model.write(); let a = s.admin.lock(); }\n";
        let d = diags("serve/router.rs", bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, RULE_LOCK_ORDER);
        let good = "fn f(s: &S) { let a = s.admin.lock(); let g = s.model.write(); }\n";
        assert!(diags("serve/router.rs", good).is_empty());
    }

    #[test]
    fn guards_die_with_their_block() {
        let src = "fn f(s: &S) { { let g = s.model.write(); } let a = s.admin.lock(); }\n";
        assert!(diags("serve/router.rs", src).is_empty());
    }

    #[test]
    fn acquirer_fns_participate() {
        let bad = "fn h(s: &S) { let g = s.model.write(); let a = admin_guard(s); }\n";
        assert_eq!(diags("serve/router.rs", bad).len(), 1);
        let good = "fn h(s: &S) { let a = admin_guard(s); s.swap_model(m); }\n";
        assert!(diags("serve/router.rs", good).is_empty());
        let reentrant = "fn h(s: &S) { let g = s.model.write(); s.swap_model(m); }\n";
        assert_eq!(diags("serve/router.rs", reentrant).len(), 1);
    }

    #[test]
    fn dotted_model_accessor_participates() {
        let bad = "fn h(s: &S) { let g = s.model.write(); let m = s.model(); }\n";
        assert_eq!(diags("serve/router.rs", bad).len(), 1);
        let ok = "fn h(s: &S) { let m = s.model(); let a = admin_guard(s); }\n";
        assert!(diags("serve/router.rs", ok).is_empty());
    }

    #[test]
    fn acquirer_definition_site_is_not_an_event() {
        let src = "fn admin_guard(s: &S) -> G { s.admin.try_lock() }\n";
        assert!(diags("serve/router.rs", src).is_empty());
    }
}
