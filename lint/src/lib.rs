//! cocoa-lint: a repo-native static invariant checker for the cocoa
//! workspace.
//!
//! The main crate documents three contracts that ordinary tests catch
//! only probabilistically: the no-panic discipline on serving/parsing
//! surfaces, the three-executor determinism invariant (no wall clock or
//! hash-ordered iteration inside rounds), and unsafe/lock hygiene. This
//! crate enforces them *statically*, with `file:line` diagnostics and a
//! JSON report for CI. Rules, scope, and the waiver syntax are
//! catalogued in `ANALYSIS.md` at the repository root.
//!
//! The checker is dependency-free by design — a hand-rolled lexer
//! ([`lexer`]), a per-file analysis pass ([`analysis`]), token-pattern
//! rules ([`rules`]) and renderers ([`report`]). It parses nothing it
//! does not need: rules operate on token adjacency, brace depth, and
//! comment geometry, which keeps the whole tool small enough to audit
//! in one sitting.

pub mod analysis;
pub mod lexer;
pub mod report;
pub mod rules;

use report::Report;
use rules::Diagnostic;
use std::fs;
use std::path::{Path, PathBuf};

/// Lint every `.rs` file under `root`. `enabled_rules` empty = all
/// rules. Files are visited in sorted path order so output (and the
/// JSON artifact) is stable across runs and machines.
pub fn lint_root(root: &Path, enabled_rules: &[String]) -> Result<Report, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut diagnostics: Vec<Diagnostic> = Vec::new();
    for path in &files {
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => return Err(format!("read {}: {e}", path.display())),
        };
        let rel = rel_path(root, path);
        let fa = analysis::FileAnalysis::build(&rel, &src);
        for d in rules::check_file(&fa) {
            if enabled_rules.is_empty() || enabled_rules.iter().any(|r| r == d.rule) {
                diagnostics.push(d);
            }
        }
    }
    diagnostics.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(Report {
        root: root.display().to_string(),
        files_scanned: files.len(),
        diagnostics,
    })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => return Err(format!("read_dir {}: {e}", dir.display())),
    };
    for entry in entries {
        let entry = match entry {
            Ok(e) => e,
            Err(e) => return Err(format!("read_dir {}: {e}", dir.display())),
        };
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<String> = rel
        .components()
        .map(|c| c.as_os_str().to_string_lossy().to_string())
        .collect();
    parts.join("/")
}

fn usage() -> String {
    let mut s = String::new();
    s.push_str("cocoa-lint: invariant checker for the cocoa workspace\n");
    s.push_str("usage: cocoa-lint [--root DIR] [--format text|json] [--out FILE]\n");
    s.push_str("                  [--rules a,b,...] [--list-rules]\n");
    s.push_str("exit codes: 0 clean, 1 violations found, 2 usage or io error\n");
    s
}

/// The whole CLI as a library function returning the process exit code,
/// so integration tests (and the fixture self-checks) can drive it
/// in-process instead of spawning binaries.
pub fn cli_run(args: &[String]) -> i32 {
    let mut root = PathBuf::from("rust/src");
    let mut json = false;
    let mut out_path: Option<PathBuf> = None;
    let mut rules_filter: Vec<String> = Vec::new();
    let mut i = 0usize;
    while i < args.len() {
        let arg = args[i].as_str();
        let value = args.get(i + 1).cloned();
        match arg {
            "--help" | "-h" => {
                print!("{}", usage());
                return 0;
            }
            "--list-rules" => {
                for (name, desc) in rules::ALL_RULES {
                    println!("{name}: {desc}");
                }
                return 0;
            }
            "--root" => {
                let Some(v) = value else {
                    eprintln!("--root needs a value");
                    return 2;
                };
                root = PathBuf::from(v);
                i += 1;
            }
            "--format" => {
                let Some(v) = value else {
                    eprintln!("--format needs a value");
                    return 2;
                };
                match v.as_str() {
                    "text" => json = false,
                    "json" => json = true,
                    other => {
                        eprintln!("unknown format {other:?} (expected text or json)");
                        return 2;
                    }
                }
                i += 1;
            }
            "--out" => {
                let Some(v) = value else {
                    eprintln!("--out needs a value");
                    return 2;
                };
                out_path = Some(PathBuf::from(v));
                i += 1;
            }
            "--rules" => {
                let Some(v) = value else {
                    eprintln!("--rules needs a value");
                    return 2;
                };
                rules_filter = v.split(',').map(|s| s.trim().to_string()).collect();
                i += 1;
            }
            other => {
                eprintln!("unknown argument {other:?}\n{}", usage());
                return 2;
            }
        }
        i += 1;
    }
    let report = match lint_root(&root, &rules_filter) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("cocoa-lint: {e}");
            return 2;
        }
    };
    let rendered = if json {
        report.to_json()
    } else {
        report.to_text()
    };
    if let Some(p) = &out_path {
        if let Err(e) = fs::write(p, &rendered) {
            eprintln!("cocoa-lint: write {}: {e}", p.display());
            return 2;
        }
    }
    print!("{rendered}");
    // Clean tree exits 0; any violation exits 1 (2 is usage/io).
    i32::from(!report.clean())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_path_is_slash_separated() {
        let root = Path::new("/a/b");
        let p = Path::new("/a/b/serve/http.rs");
        assert_eq!(rel_path(root, p), "serve/http.rs");
    }

    #[test]
    fn cli_rejects_bad_flags() {
        assert_eq!(cli_run(&["--format".to_string()]), 2);
        assert_eq!(cli_run(&["--format".to_string(), "xml".to_string()]), 2);
        assert_eq!(cli_run(&["--bogus".to_string()]), 2);
    }

    #[test]
    fn cli_errors_on_missing_root() {
        let args = vec!["--root".to_string(), "/nonexistent/cocoa".to_string()];
        assert_eq!(cli_run(&args), 2);
    }
}
