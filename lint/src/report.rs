//! Rendering: human-readable text with `file:line:` prefixes (clickable
//! in most editors and CI logs) and a hand-rolled machine-readable JSON
//! document (mirroring the main crate's dependency-free `util::json`
//! school — no serde).

use crate::rules::Diagnostic;

pub struct Report {
    /// The scanned root as given on the command line.
    pub root: String,
    pub files_scanned: usize,
    /// Sorted by (path, line, rule).
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            let row = format!("{}/{}:{}: [{}] {}\n", self.root, d.path, d.line, d.rule, d.msg);
            out.push_str(&row);
        }
        let tail = format!(
            "cocoa-lint: {} files scanned, {} violations\n",
            self.files_scanned,
            self.diagnostics.len()
        );
        out.push_str(&tail);
        out
    }

    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str("  \"tool\": \"cocoa-lint\",\n");
        out.push_str(&format!("  \"root\": \"{}\",\n", json_escape(&self.root)));
        out.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        out.push_str(&format!("  \"violations\": {},\n", self.diagnostics.len()));
        out.push_str("  \"diagnostics\": [\n");
        for (i, d) in self.diagnostics.iter().enumerate() {
            let last = i + 1 == self.diagnostics.len();
            let sep = if last { "" } else { "," };
            let row = format!(
                "    {{\"rule\": \"{}\", \"path\": \"{}\", \"line\": {}, \"msg\": \"{}\"}}{sep}\n",
                d.rule,
                json_escape(&d.path),
                d.line,
                json_escape(&d.msg)
            );
            out.push_str(&row);
        }
        out.push_str("  ]\n");
        out.push_str("}\n");
        out
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let code = format!("\\u{:04x}", c as u32);
                out.push_str(&code);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::RULE_NO_PANIC;

    fn sample() -> Report {
        Report {
            root: "rust/src".to_string(),
            files_scanned: 2,
            diagnostics: vec![Diagnostic {
                rule: RULE_NO_PANIC,
                path: "serve/http.rs".to_string(),
                line: 7,
                msg: "`.unwrap()` is forbidden on a no-panic surface".to_string(),
            }],
        }
    }

    #[test]
    fn text_has_clickable_locations() {
        let txt = sample().to_text();
        assert!(txt.contains("rust/src/serve/http.rs:7: [no_panic]"), "{txt}");
        assert!(txt.contains("1 violations"));
    }

    #[test]
    fn json_is_balanced_and_escaped() {
        let mut r = sample();
        r.diagnostics[0].msg = "quote \" backslash \\ newline \n done".to_string();
        let js = r.to_json();
        assert!(js.contains("\\\" backslash \\\\ newline \\n done"));
        assert_eq!(js.matches('{').count(), js.matches('}').count());
        assert!(js.contains("\"violations\": 1,"));
        assert!(js.contains("\"files_scanned\": 2,"));
    }

    #[test]
    fn empty_report_is_clean_valid_json() {
        let r = Report {
            root: "rust/src".to_string(),
            files_scanned: 0,
            diagnostics: Vec::new(),
        };
        assert!(r.clean());
        let js = r.to_json();
        assert!(js.contains("\"violations\": 0,"));
        assert!(js.contains("\"diagnostics\": [\n  ]"), "{js}");
    }

    #[test]
    fn control_chars_become_unicode_escapes() {
        assert_eq!(json_escape("a\u{01}b"), "a\\u0001b");
    }
}
