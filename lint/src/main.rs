//! Thin binary wrapper: all logic lives in the library so integration
//! tests can drive the CLI in-process and assert exit codes.

use std::process::exit;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    exit(cocoa_lint::cli_run(&args));
}
