//! Per-file analysis built on the raw token stream: brace depth,
//! `#[cfg(test)]` masking, attribute spans, comment geometry (for
//! `// SAFETY:` adjacency) and `lint:allow` waiver extraction.
//!
//! Rules never look at raw source text; everything they need is
//! precomputed here so each rule is a small scan over `toks` with
//! parallel `depth` / `in_test` / `in_attr` vectors.

use crate::lexer::{lex, Tok, TokKind};
use std::collections::BTreeSet;

/// An inline waiver comment: `// lint:allow(rule_a, rule_b) -- reason`.
/// A waiver suppresses matching diagnostics on its own line and on the
/// line directly below it (so it can sit above the offending statement).
#[derive(Clone, Debug)]
pub struct Waiver {
    pub line: u32,
    pub rules: Vec<String>,
    pub has_reason: bool,
}

/// Everything the rules need to know about one source file.
pub struct FileAnalysis {
    /// Path relative to the lint root, `/`-separated.
    pub rel: String,
    /// Significant tokens: comments stripped.
    pub toks: Vec<Tok>,
    /// Brace-nesting depth of each token in `toks`. A `{` carries the
    /// depth *outside* its block; its matching `}` carries the same
    /// value, and everything between them is deeper.
    pub depth: Vec<u32>,
    /// True for tokens inside `#[test]` / `#[cfg(test)]` items.
    pub in_test: Vec<bool>,
    /// True for tokens inside any `#[…]` / `#![…]` attribute.
    pub in_attr: Vec<bool>,
    pub waivers: Vec<Waiver>,
    comment_lines: BTreeSet<u32>,
    safety_lines: BTreeSet<u32>,
}

impl FileAnalysis {
    pub fn build(rel: &str, src: &str) -> FileAnalysis {
        let all = lex(src);
        let mut comment_lines = BTreeSet::new();
        let mut safety_lines = BTreeSet::new();
        let mut waivers = Vec::new();
        for t in &all {
            if t.kind != TokKind::Comment {
                continue;
            }
            let span = t.line..=t.line + t.extra_lines;
            comment_lines.extend(span.clone());
            if t.text.contains("SAFETY:") {
                safety_lines.extend(span);
            }
            if let Some(w) = parse_waiver(&t.text, t.line) {
                waivers.push(w);
            }
        }
        let toks: Vec<Tok> = all.into_iter().filter(|t| t.kind != TokKind::Comment).collect();
        let depth = compute_depth(&toks);
        let in_attr = compute_attr_mask(&toks);
        let in_test = compute_test_mask(&toks, &depth, &in_attr);
        FileAnalysis {
            rel: rel.to_string(),
            toks,
            depth,
            in_test,
            in_attr,
            waivers,
            comment_lines,
            safety_lines,
        }
    }

    /// Is a diagnostic of `rule` on `line` suppressed by a waiver?
    pub fn waived(&self, rule: &str, line: u32) -> bool {
        for w in &self.waivers {
            if w.line != line && w.line + 1 != line {
                continue;
            }
            if w.rules.iter().any(|r| r == rule) {
                return true;
            }
        }
        false
    }

    /// Is there a `SAFETY:` comment on this line, or ending directly
    /// above it (walking up through a contiguous run of comment lines)?
    pub fn safety_adjacent(&self, line: u32) -> bool {
        if self.safety_lines.contains(&line) {
            return true;
        }
        let mut l = line;
        while l > 1 {
            l -= 1;
            if !self.comment_lines.contains(&l) {
                return false;
            }
            if self.safety_lines.contains(&l) {
                return true;
            }
        }
        false
    }

    pub fn prev_tok(&self, i: usize) -> Option<&Tok> {
        i.checked_sub(1).and_then(|j| self.toks.get(j))
    }

    pub fn next_tok(&self, i: usize) -> Option<&Tok> {
        self.toks.get(i + 1)
    }
}

fn parse_waiver(text: &str, line: u32) -> Option<Waiver> {
    let at = text.find("lint:allow(")?;
    let rest = &text[at + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let has_reason = rest[close..].contains("--");
    Some(Waiver {
        line,
        rules,
        has_reason,
    })
}

fn compute_depth(toks: &[Tok]) -> Vec<u32> {
    let mut out = Vec::with_capacity(toks.len());
    let mut cur = 0u32;
    for t in toks {
        if t.is(TokKind::Punct, "{") {
            out.push(cur);
            cur += 1;
        } else if t.is(TokKind::Punct, "}") {
            cur = cur.saturating_sub(1);
            out.push(cur);
        } else {
            out.push(cur);
        }
    }
    out
}

/// Mark every token belonging to an attribute: `#` (optional `!`) `[` …
/// matching `]`. Keeps rules like the indexing check from tripping on
/// `#[derive(…)]` brackets.
fn compute_attr_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if !toks[i].is(TokKind::Punct, "#") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if toks.get(j).is_some_and(|t| t.is(TokKind::Punct, "!")) {
            j += 1;
        }
        if !toks.get(j).is_some_and(|t| t.is(TokKind::Punct, "[")) {
            i += 1;
            continue;
        }
        // Walk to the matching `]`.
        let mut brackets = 0i32;
        let mut end = j;
        while end < toks.len() {
            if toks[end].is(TokKind::Punct, "[") {
                brackets += 1;
            } else if toks[end].is(TokKind::Punct, "]") {
                brackets -= 1;
                if brackets == 0 {
                    break;
                }
            }
            end += 1;
        }
        let stop = end.min(toks.len().saturating_sub(1));
        for m in mask.iter_mut().take(stop + 1).skip(i) {
            *m = true;
        }
        i = end + 1;
    }
    mask
}

/// Mark tokens of items annotated `#[test]` or `#[cfg(test)]` (and any
/// attribute whose `cfg` predicate mentions `test`, e.g.
/// `#[cfg(all(test, feature = "x"))]`). The span runs from the attribute
/// through the item's closing `}` (or `;` for block-less items).
fn compute_test_mask(toks: &[Tok], depth: &[u32], in_attr: &[bool]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if !toks[i].is(TokKind::Punct, "#") || !in_attr[i] {
            i += 1;
            continue;
        }
        // Find this attribute's extent and collect its inner idents.
        let mut end = i;
        while end + 1 < toks.len() && in_attr[end + 1] {
            // Stop at the `]` that closes *this* attribute: the next
            // token after it is either non-attr or a fresh `#`.
            if toks[end].is(TokKind::Punct, "]") && toks[end + 1].is(TokKind::Punct, "#") {
                break;
            }
            end += 1;
        }
        let inner: Vec<&str> = toks[i..=end]
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        let is_test_attr = match inner.first() {
            Some(&"test") => true,
            Some(&"cfg") => inner.iter().any(|s| *s == "test"),
            _ => false,
        };
        if !is_test_attr {
            i = end + 1;
            continue;
        }
        // Scan forward past further attributes to the item body.
        let mut k = end + 1;
        let mut body_start = None;
        while k < toks.len() {
            if in_attr[k] {
                k += 1;
                continue;
            }
            if toks[k].is(TokKind::Punct, ";") {
                break; // block-less item, e.g. `#[cfg(test)] use …;`
            }
            if toks[k].is(TokKind::Punct, "{") {
                body_start = Some(k);
                break;
            }
            k += 1;
        }
        let span_end = match body_start {
            Some(s) => find_matching_brace(toks, depth, s),
            None => k,
        };
        let stop = span_end.min(toks.len().saturating_sub(1));
        for m in mask.iter_mut().take(stop + 1).skip(i) {
            *m = true;
        }
        i = stop + 1;
    }
    mask
}

/// Index of the `}` matching the `{` at `open` (same recorded depth).
fn find_matching_brace(toks: &[Tok], depth: &[u32], open: usize) -> usize {
    let d = depth[open];
    let mut j = open + 1;
    while j < toks.len() {
        if toks[j].is(TokKind::Punct, "}") && depth[j] == d {
            return j;
        }
        j += 1;
    }
    toks.len().saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_mask_covers_cfg_test_mod() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\nfn after() {}\n";
        let fa = FileAnalysis::build("f.rs", src);
        let mut unwraps = Vec::new();
        for (t, masked) in fa.toks.iter().zip(fa.in_test.iter()) {
            if t.text == "unwrap" {
                unwraps.push(*masked);
            }
        }
        assert_eq!(unwraps, vec![false, true]);
        let after = fa.toks.iter().position(|t| t.text == "after").expect("after");
        assert!(!fa.in_test[after]);
    }

    #[test]
    fn test_mask_covers_test_fn_with_stacked_attrs() {
        let src = "#[test]\n#[ignore]\nfn t() { z.unwrap(); }\nfn live() {}\n";
        let fa = FileAnalysis::build("f.rs", src);
        let z = fa.toks.iter().position(|t| t.text == "z").expect("z");
        assert!(fa.in_test[z]);
        let live = fa.toks.iter().position(|t| t.text == "live").expect("live");
        assert!(!fa.in_test[live]);
    }

    #[test]
    fn attr_mask_covers_derives() {
        let src = "#[derive(Clone, Debug)]\nstruct S;\n";
        let fa = FileAnalysis::build("f.rs", src);
        let clone = fa.toks.iter().position(|t| t.text == "Clone").expect("Clone");
        assert!(fa.in_attr[clone]);
        let s = fa.toks.iter().position(|t| t.text == "S").expect("S");
        assert!(!fa.in_attr[s]);
    }

    #[test]
    fn waiver_parsing_and_application() {
        let src = "// lint:allow(no_panic) -- startup config is load-bearing\nlet x = v.unwrap();\n// lint:allow(a, b)\n";
        let fa = FileAnalysis::build("f.rs", src);
        assert_eq!(fa.waivers.len(), 2);
        assert!(fa.waivers[0].has_reason);
        assert!(!fa.waivers[1].has_reason);
        assert!(fa.waived("no_panic", 1));
        assert!(fa.waived("no_panic", 2));
        assert!(!fa.waived("no_panic", 3));
        assert!(fa.waived("b", 3));
    }

    #[test]
    fn safety_adjacency_through_comment_runs() {
        let src = "// SAFETY: three lines of\n// justification for the\n// following block\nunsafe { a() }\n\nunsafe { b() }\n";
        let fa = FileAnalysis::build("f.rs", src);
        assert!(fa.safety_adjacent(4));
        assert!(!fa.safety_adjacent(6));
    }

    #[test]
    fn safety_adjacency_does_not_jump_blank_lines() {
        let src = "// SAFETY: stale\n\nunsafe { a() }\n";
        let fa = FileAnalysis::build("f.rs", src);
        assert!(!fa.safety_adjacent(3));
    }

    #[test]
    fn depth_matches_braces() {
        let src = "fn f() { if x { y(); } }";
        let fa = FileAnalysis::build("f.rs", src);
        let y = fa.toks.iter().position(|t| t.text == "y").expect("y");
        assert_eq!(fa.depth[y], 2);
        let f = fa.toks.iter().position(|t| t.text == "f").expect("f");
        assert_eq!(fa.depth[f], 0);
    }
}
