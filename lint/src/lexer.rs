//! A hand-rolled Rust lexer: just enough of the language to drive the
//! token-pattern rules in [`crate::rules`], with zero dependencies.
//!
//! It is *not* a parser. It produces a flat token stream with line
//! numbers, which is what the rules need: identifier context (`.unwrap(`
//! vs `unwrap_or(`), comment adjacency (`// SAFETY:`), brace depth
//! (lock-guard lifetimes), and attribute spans (`#[cfg(test)]` masking).
//! The tricky part of lexing Rust at this level is not grammar but
//! *strings*: raw strings, byte strings, char-vs-lifetime ambiguity, and
//! nested block comments all have to be handled or every rule downstream
//! reports phantom hits from inside literals.

/// What kind of token this is. `Comment` tokens are kept in the stream so
/// the analysis layer can extract waivers and `SAFETY:` adjacency before
/// filtering them out of the significant-token view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    Number,
    Str,
    Char,
    Punct,
    Comment,
}

/// One token. `line` is 1-based and points at the token's first
/// character; multi-line tokens (block comments, raw strings) record how
/// many newlines they span in `extra_lines`.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub extra_lines: u32,
}

impl Tok {
    fn new(kind: TokKind, text: String, line: u32) -> Tok {
        let extra_lines = text.matches('\n').count() as u32;
        Tok {
            kind,
            text,
            line,
            extra_lines,
        }
    }

    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
}

/// Lex a whole source file into a flat token stream.
pub fn lex(src: &str) -> Vec<Tok> {
    let lexer = Lexer {
        chars: src.chars().collect(),
        i: 0,
        line: 1,
        out: Vec::new(),
    };
    lexer.run()
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: u32,
    out: Vec<Tok>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn run(mut self) -> Vec<Tok> {
        while let Some(c) = self.peek(0) {
            match c {
                '\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ if c.is_whitespace() => self.i += 1,
                '/' if self.peek(1) == Some('/') => self.line_comment(),
                '/' if self.peek(1) == Some('*') => self.block_comment(),
                'r' | 'b' => {
                    // Raw/byte string prefixes share their first letter
                    // with plain identifiers; try the string form first.
                    if !self.rawish_string() {
                        self.ident();
                    }
                }
                '"' => self.string(),
                '\'' => self.char_or_lifetime(),
                _ if c == '_' || c.is_alphabetic() => self.ident(),
                _ if c.is_ascii_digit() => self.number(),
                _ => {
                    self.out.push(Tok::new(TokKind::Punct, c.to_string(), self.line));
                    self.i += 1;
                }
            }
        }
        self.out
    }

    fn slice(&self, start: usize) -> String {
        self.chars[start..self.i.min(self.chars.len())].iter().collect()
    }

    fn line_comment(&mut self) {
        let start = self.i;
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            self.i += 1;
        }
        let text = self.slice(start);
        self.out.push(Tok::new(TokKind::Comment, text, self.line));
    }

    fn block_comment(&mut self) {
        let start = self.i;
        let start_line = self.line;
        let mut depth = 0usize;
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                self.line += 1;
                self.i += 1;
            } else if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                self.i += 2;
            } else if c == '*' && self.peek(1) == Some('/') {
                depth = depth.saturating_sub(1);
                self.i += 2;
                if depth == 0 {
                    break;
                }
            } else {
                self.i += 1;
            }
        }
        let text = self.slice(start);
        self.out.push(Tok::new(TokKind::Comment, text, start_line));
    }

    /// Raw and byte string forms: `r"…"`, `r#"…"#` (any hash count),
    /// `b"…"`, `br"…"`, `br#"…"#`. Returns false (consuming nothing) if
    /// the `r`/`b` at the cursor is actually the start of an identifier,
    /// a raw identifier (`r#match`), or a byte char (`b'x'` — handled by
    /// the ident + char paths).
    fn rawish_string(&mut self) -> bool {
        let mut j = self.i;
        let mut raw = false;
        if self.chars.get(j) == Some(&'b') {
            j += 1;
        }
        if self.chars.get(j) == Some(&'r') {
            j += 1;
            raw = true;
        }
        if !raw {
            // b"…" — plain byte string; reuse the escaped-string scanner.
            if self.chars.get(j) != Some(&'"') {
                return false;
            }
            self.i = j;
            self.string();
            return true;
        }
        let mut hashes = 0usize;
        while self.chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if self.chars.get(j) != Some(&'"') {
            return false;
        }
        let start = self.i;
        let start_line = self.line;
        self.i = j + 1;
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                self.line += 1;
                self.i += 1;
            } else if c == '"' {
                let mut k = self.i + 1;
                let mut h = 0usize;
                while h < hashes && self.chars.get(k) == Some(&'#') {
                    h += 1;
                    k += 1;
                }
                self.i = k;
                if h == hashes {
                    break;
                }
            } else {
                self.i += 1;
            }
        }
        let text = self.slice(start);
        self.out.push(Tok::new(TokKind::Str, text, start_line));
        true
    }

    fn string(&mut self) {
        let start = self.i;
        let start_line = self.line;
        self.i += 1; // opening quote
        while let Some(c) = self.peek(0) {
            match c {
                '\\' => self.i += 2,
                '"' => {
                    self.i += 1;
                    break;
                }
                '\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
        let text = self.slice(start);
        self.out.push(Tok::new(TokKind::Str, text, start_line));
    }

    /// Disambiguate `'a'` / `'\n'` (char literals) from `'static` / `'a`
    /// (lifetimes). A quote followed by an escape is always a char; a
    /// quote followed by an ident run is a char only when the run is one
    /// character long and a closing quote follows.
    fn char_or_lifetime(&mut self) {
        let start = self.i;
        if self.peek(1) == Some('\\') {
            self.i += 2; // quote + backslash
            // Skip the escape body (covers \', \\, \n, \u{…}) up to the
            // closing quote.
            while let Some(c) = self.peek(0) {
                self.i += 1;
                if c == '\'' {
                    break;
                }
            }
            let text = self.slice(start);
            self.out.push(Tok::new(TokKind::Char, text, self.line));
            return;
        }
        let mut j = self.i + 1;
        while self.chars.get(j).is_some_and(|c| *c == '_' || c.is_alphanumeric()) {
            j += 1;
        }
        if j == self.i + 2 && self.chars.get(j) == Some(&'\'') {
            // 'x' — single-character literal.
            self.i = j + 1;
            let text = self.slice(start);
            self.out.push(Tok::new(TokKind::Char, text, self.line));
        } else if j > self.i + 1 {
            // 'ident — a lifetime.
            self.i = j;
            let text = self.slice(start);
            self.out.push(Tok::new(TokKind::Lifetime, text, self.line));
        } else if self.peek(1).is_some() && self.peek(2) == Some('\'') {
            // Non-alphanumeric char literal, e.g. `' '` or `'.'`.
            self.i += 3;
            let text = self.slice(start);
            self.out.push(Tok::new(TokKind::Char, text, self.line));
        } else {
            self.out.push(Tok::new(TokKind::Punct, "'".to_string(), self.line));
            self.i += 1;
        }
    }

    fn ident(&mut self) {
        let start = self.i;
        while self.peek(0).is_some_and(|c| c == '_' || c.is_alphanumeric()) {
            self.i += 1;
        }
        let text = self.slice(start);
        self.out.push(Tok::new(TokKind::Ident, text, self.line));
    }

    fn number_continues(&self, c: char, prev: char) -> bool {
        if c.is_ascii_alphanumeric() || c == '_' {
            return true;
        }
        if c == '.' && prev != '.' {
            // Consume the dot only when a digit follows, so `0..n`
            // ranges and `1.max(2)` method calls stay intact.
            return self.peek(1).is_some_and(|d| d.is_ascii_digit());
        }
        (c == '+' || c == '-') && (prev == 'e' || prev == 'E')
    }

    /// Numbers: ints, floats, hex/oct/bin, `_` separators, type
    /// suffixes, exponents with signs.
    fn number(&mut self) {
        let start = self.i;
        self.i += 1;
        while let Some(c) = self.peek(0) {
            if !self.number_continues(c, self.chars[self.i - 1]) {
                break;
            }
            self.i += 1;
        }
        let text = self.slice(start);
        self.out.push(Tok::new(TokKind::Number, text, self.line));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let toks = kinds("let x = y.unwrap();");
        let texts: Vec<&str> = toks.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(texts, vec!["let", "x", "=", "y", ".", "unwrap", "(", ")", ";"]);
    }

    #[test]
    fn strings_hide_their_contents() {
        let toks = kinds(r#"let s = "x.unwrap()"; s"#);
        assert!(toks.iter().all(|(k, t)| *k != TokKind::Ident || t != "unwrap"));
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::Str).count(), 1);
    }

    #[test]
    fn raw_strings_with_hashes() {
        let src = "let s = r#\"a \" b.unwrap()\"# ; done";
        let toks = kinds(src);
        assert!(toks.iter().any(|(_, t)| t == "done"));
        assert!(toks.iter().all(|(_, t)| t != "unwrap"));
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let toks = kinds(r#"let a = b"\r\n\r\n"; let c = b'x';"#);
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::Str).count(), 1);
        assert!(toks.iter().any(|(k, _)| *k == TokKind::Char));
    }

    #[test]
    fn lifetimes_vs_chars() {
        let toks = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::Lifetime).count(), 2);
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::Char).count(), 1);
    }

    #[test]
    fn escaped_char_literals() {
        let toks = kinds(r"let nl = '\n'; let q = '\''; let u = '\u{1F600}'; x");
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::Char).count(), 3);
        assert!(toks.iter().any(|(_, t)| t == "x"));
    }

    #[test]
    fn punctuation_char_literals() {
        let toks = kinds("line.split(' ').find(|c| c == '.')");
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::Char).count(), 2);
    }

    #[test]
    fn nested_block_comments() {
        let toks = kinds("a /* outer /* inner */ still comment */ b");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, vec!["a", "b"]);
    }

    #[test]
    fn line_numbers_track_multiline_tokens() {
        let src = "a\n/* one\ntwo */\nb";
        let toks = lex(src);
        let b = toks.iter().find(|t| t.text == "b").expect("b lexed");
        assert_eq!(b.line, 4);
        let c = toks.iter().find(|t| t.kind == TokKind::Comment).expect("comment lexed");
        assert_eq!(c.line, 2);
        assert_eq!(c.extra_lines, 1);
    }

    #[test]
    fn numbers_do_not_eat_ranges_or_methods() {
        let texts: Vec<String> = kinds("for i in 0..n { 1.max(2); 3.5e-2; }")
            .into_iter()
            .map(|(_, t)| t)
            .collect();
        assert!(texts.contains(&"0".to_string()));
        assert!(texts.contains(&"n".to_string()));
        assert!(texts.contains(&"max".to_string()));
        assert!(texts.contains(&"3.5e-2".to_string()));
    }

    #[test]
    fn underscored_numbers_and_suffixes() {
        let texts: Vec<String> = kinds("1_000_000u64 + 0xFF_EC + 0b1010")
            .into_iter()
            .map(|(_, t)| t)
            .collect();
        assert_eq!(texts[0], "1_000_000u64");
        assert!(texts.contains(&"0xFF_EC".to_string()));
    }

    #[test]
    fn tok_is_helper() {
        let toks = lex("fn main() {}");
        assert!(toks[0].is(TokKind::Ident, "fn"));
        assert!(!toks[0].is(TokKind::Punct, "fn"));
    }
}
