//! End-to-end tests over the fixture corpus and the real tree.
//!
//! Three guarantees live here:
//! 1. every rule family fires on its known-bad fixture (exact counts,
//!    so a silently weakened rule is a test failure);
//! 2. a waiver with a reason suppresses its diagnostic;
//! 3. the clean-tree self-check — the real `rust/src` lints green, so
//!    the CI lint gate stays green by construction, and the CLI's
//!    non-zero failure mode is proven against the bad fixture tree
//!    rather than by breaking main.

use cocoa_lint::report::Report;
use cocoa_lint::{cli_run, lint_root};
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn real_src() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust/src")
}

fn count(report: &Report, path: &str, rule: &str) -> usize {
    let mut n = 0;
    for d in &report.diagnostics {
        if d.path == path && d.rule == rule {
            n += 1;
        }
    }
    n
}

#[test]
fn bad_tree_triggers_every_rule_family() {
    let report = lint_root(&fixture("bad_tree"), &[]).expect("lint bad_tree");
    assert_eq!(report.files_scanned, 7);
    let diags = &report.diagnostics;
    assert_eq!(count(&report, "serve/http.rs", "no_panic"), 4, "{diags:?}");
    assert_eq!(count(&report, "coordinator/pool.rs", "determinism"), 6, "{diags:?}");
    assert_eq!(count(&report, "coordinator/wire.rs", "arith_overflow"), 2, "{diags:?}");
    assert_eq!(count(&report, "driver/train.rs", "determinism"), 1, "{diags:?}");
    assert_eq!(count(&report, "linalg/sparse.rs", "unsafe_safety"), 1, "{diags:?}");
    assert_eq!(count(&report, "serve/router.rs", "lock_order"), 1, "{diags:?}");
    assert_eq!(count(&report, "telemetry/writer.rs", "no_panic"), 1, "{diags:?}");
    assert_eq!(count(&report, "telemetry/writer.rs", "determinism"), 1, "{diags:?}");
    assert_eq!(report.diagnostics.len(), 17, "{diags:?}");
}

#[test]
fn diagnostics_are_sorted_and_located() {
    let report = lint_root(&fixture("bad_tree"), &[]).expect("lint bad_tree");
    let mut keys: Vec<(String, u32)> = Vec::new();
    for d in &report.diagnostics {
        assert!(d.line > 0, "diagnostic without a line: {d:?}");
        keys.push((d.path.clone(), d.line));
    }
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "output must be stable and sorted");
}

#[test]
fn waiver_fixture_is_suppressed() {
    let report = lint_root(&fixture("waived_tree"), &[]).expect("lint waived_tree");
    assert!(report.clean(), "{:?}", report.diagnostics);
}

#[test]
fn rules_filter_narrows_output() {
    let only = vec!["lock_order".to_string()];
    let report = lint_root(&fixture("bad_tree"), &only).expect("lint bad_tree");
    assert_eq!(report.diagnostics.len(), 1, "{:?}", report.diagnostics);
    assert_eq!(report.diagnostics[0].rule, "lock_order");
}

/// The clean-tree guarantee: the real sources must produce zero
/// diagnostics (with at most documented inline waivers). This is the
/// test that keeps the CI `lint` job green by construction.
#[test]
fn clean_tree_self_check_real_sources_lint_green() {
    let report = lint_root(&real_src(), &[]).expect("lint rust/src");
    assert!(report.files_scanned > 50, "walk found the real tree");
    assert!(report.clean(), "rust/src must lint clean: {:#?}", report.diagnostics);
}

/// Negative CI proof: the bad fixture tree makes the CLI exit 1 and
/// still emit the JSON artifact, without having to break main.
#[test]
fn cli_exit_codes_and_json_artifact() {
    let out = std::env::temp_dir().join("cocoa_lint_fixture_report.json");
    let args = vec![
        "--root".to_string(),
        fixture("bad_tree").display().to_string(),
        "--format".to_string(),
        "json".to_string(),
        "--out".to_string(),
        out.display().to_string(),
    ];
    assert_eq!(cli_run(&args), 1, "violations must exit 1");
    let js = std::fs::read_to_string(&out).expect("json artifact written");
    assert!(js.contains("\"tool\": \"cocoa-lint\""), "{js}");
    assert!(js.contains("\"rule\": \"lock_order\""), "{js}");
    assert!(js.contains("\"violations\": 17"), "{js}");
    assert_eq!(js.matches('{').count(), js.matches('}').count());
    std::fs::remove_file(&out).ok();
}

#[test]
fn cli_clean_tree_exits_zero() {
    let args = vec!["--root".to_string(), real_src().display().to_string()];
    assert_eq!(cli_run(&args), 0, "clean tree must exit 0");
}
