//! Fixture: a violation suppressed by a waiver carrying a reason — the
//! tree must lint clean.

pub fn score(w: &[f64]) -> f64 {
    // lint:allow(no_panic) -- fixture: caller guarantees a first weight
    w.first().copied().unwrap()
}
