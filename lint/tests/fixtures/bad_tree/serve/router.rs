//! Fixture: inverted nested lock acquisition — `model` taken first,
//! then `admin`, against the declared admin < model < w_shared order.

pub fn reload(state: &AppState) -> Result<(), String> {
    let guard = state.model.write();
    let _admin = state.admin.lock();
    drop(guard);
    Ok(())
}
