//! Fixture: a panicking request handler. Every construct below is a
//! distinct `no_panic` target (unwrap, expect, direct indexing,
//! panic!). This file is test data — it is never compiled.

pub fn handle(buf: &[u8]) -> String {
    let head = std::str::from_utf8(buf).unwrap();
    let first = head.lines().next().expect("request line");
    let b = buf[0];
    if b == 0 {
        panic!("empty request");
    }
    first.to_string()
}
