//! Fixture: a telemetry export surface that reads the wall clock (the
//! recorder must go through `util::timer::trace_now_us`) and panics on
//! a malformed event instead of returning `Err`.

pub fn export_event(buf: &Vec<u8>) -> u64 {
    let started = Instant::now();
    let first = buf.first().unwrap();
    stamp(started, first)
}
