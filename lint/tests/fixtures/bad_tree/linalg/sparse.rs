//! Fixture: an unsafe block with no justification comment.

pub fn row_dot(idx: &[u32], vals: &[f64], w: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (j, &c) in idx.iter().enumerate() {
        // missing justification comment: this is what the rule catches
        acc += unsafe { vals.get_unchecked(j) * w.get_unchecked(c as usize) };
    }
    acc
}
