//! Fixture: completion-order gather in a driver round loop — the order
//! of `out` depends on which worker finished first.

pub fn collect_updates(rx: Receiver<Update>) -> Vec<Update> {
    let mut out = Vec::new();
    for r in rx {
        out.push(r);
    }
    out
}
