// Known-bad fixture for `arith_overflow`: frame-size arithmetic that
// wraps silently instead of going through checked_add/checked_mul.
fn frame_len(header_bytes: &[u8], words: usize) -> usize {
    let body = 8 * words;
    body + header_bytes.len()
}
