//! Fixture: determinism violations inside a round loop — hash-ordered
//! collections, wall clock, and an arrival-order channel gather.

use std::collections::HashMap;
use std::time::Instant;

pub fn gather(rx: std::sync::mpsc::Receiver<f64>) -> Vec<f64> {
    let t0 = Instant::now();
    let seen: HashMap<usize, f64> = HashMap::new();
    let mut out = Vec::new();
    for r in rx {
        out.push(r);
    }
    let _ = (t0, seen.len());
    out
}
